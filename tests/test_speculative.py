"""Acceptance-invariance suite for self-speculative elastic decoding.

The contract under test (docs/serving_internals.md §9 "Speculative
decoding"): with ``ElasticEngine(speculative=SpecConfig(...))``, k greedy
draft steps run at the cheap rung and ONE batched verify step at the
pinned format scores the k+1 positions per slot; only the verify format's
own argmaxes are ever committed. Therefore, under greedy sampling:

  - token streams are BIT-IDENTICAL to plain pinned-format decode for
    every slot, at ANY acceptance rate — even an adversarially poisoned
    draft rung (acceptance ~ 0) may only change speed, never tokens;
  - the paged free list stays exact across any accept/reject pattern:
    pages past a rewound ``cache_len`` are freed at the rollback,
    ``kv_pages_alloc == kv_pages_freed`` once the wave drains, and a
    neighbor's rollback never touches another slot's block-table row;
  - ``tick_trace`` splits each speculative tick into draft vs verify
    executables so the execs-per-tick invariants stay assertable.

Fast pair runs tier-1; the full {fused, densify} x {gather, paged_kernel}
x draft x k matrix is @pytest.mark.slow (CI runs it non-blocking).
"""
import numpy as np
import jax
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_stub import hypothesis, st

from repro.configs import get_reduced
from repro.core import make_anchor
from repro.core.qat import QATConfig
from repro.models import get_model
from repro.models.common import spec_accept_counts
from repro.runtime.fault import FaultInjector
from repro.serve.engine import ElasticEngine, Request
from repro.serve.policy import FormatPolicy, SpecConfig

QAT = QATConfig(formats=("mxint4", "mxint6", "mxint8"), anchor="mxint8",
                block_size=32)
PS = 8


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("smollm-135m")
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT)
    return cfg, api, params, anchor


def _engine(api, anchor, params, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("kv_page_size", PS)
    kw.setdefault("fused", False)
    return ElasticEngine(api, anchor, param_template=params, **kw)


def _reqs(cfg, n, max_new=6, plen=8, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=plen)
                    .astype(np.int32),
                    max_new=max_new)
            for i in range(n)]


def _streams(reqs):
    return [list(r.out_tokens) for r in reqs]


def _no_leak(eng):
    st_ = eng.stats
    assert st_["kv_pages_alloc"] == st_["kv_pages_freed"], \
        (st_["kv_pages_alloc"], st_["kv_pages_freed"])


def _run(setup, spec, *, n=3, max_new=6, fmt="mxint8", injector=None,
         **kw):
    cfg, api, params, anchor = setup
    eng = _engine(api, anchor, params, speculative=spec,
                  fault_injector=injector, **kw)
    reqs = _reqs(cfg, n, max_new=max_new)
    eng.generate(reqs, greedy=True, fmt_override=fmt)
    return eng, _streams(reqs)


# ---------------------------------------------------------------------------
# fast pair (tier-1): one densify/gather and one fused/paged_kernel config
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused,attn", [(False, "gather"),
                                        (True, "paged_kernel")])
def test_spec_stream_identity(setup, fused, attn):
    _, plain = _run(setup, None, fused=fused, attn_impl=attn)
    eng, spec = _run(setup, SpecConfig(draft_fmt="mxint4", k=4),
                     fused=fused, attn_impl=attn)
    assert spec == plain
    st_ = eng.stats
    assert st_["spec_ticks"] > 0
    assert st_["spec_accepted"] >= 0 and st_["spec_rejected"] >= 0
    assert st_["speculative"] == {"draft_fmt": "mxint4", "k": 4,
                                  "min_acceptance": 0.0, "window": 16}
    _no_leak(eng)


def test_spec_fewer_decode_ticks_when_accepting(setup):
    """Speculation's whole point: accepted drafts compress decode ticks.
    The toy model decodes highly repetitive streams, so acceptance is
    high and the spec engine must finish the same wave in strictly fewer
    decode ticks (tokens per tick > 1)."""
    eng_p, plain = _run(setup, None)
    eng_s, spec = _run(setup, SpecConfig(draft_fmt="mxint4", k=4))
    assert spec == plain
    assert eng_s.stats["ticks"] < eng_p.stats["ticks"]
    assert eng_s.stats["spec_accepted"] > 0


def test_spec_poisoned_draft_stream_must_still_match(setup):
    """Adversarial rung: every draft tick's mxint4 logits are NaN-poisoned
    (guard off, so the garbage drafts flow into verify). argmax of an
    all-NaN row is constant, acceptance collapses toward zero — and the
    emitted streams STILL match plain anchor decode bit for bit, because
    verify only ever commits its own argmaxes."""
    fi = FaultInjector(poison_logits={t: None for t in range(256)},
                       poison_fmt="mxint4")
    _, plain = _run(setup, None, logit_guard=False)
    eng, spec = _run(setup, SpecConfig(draft_fmt="mxint4", k=4),
                     injector=fi, logit_guard=False)
    assert spec == plain
    st_ = eng.stats
    assert st_["spec_ticks"] > 0
    assert st_["spec_rejected"] > 0
    rate = st_["spec_acceptance_rate"]
    assert rate is not None and rate < 0.5
    _no_leak(eng)


def test_spec_identity_under_mixed_scheduler(setup):
    """Speculation and the mixed chunked-admission scheduler compose:
    chunk-carrying ticks run plain mixed steps, pure-decode ticks
    speculate, and the streams still match plain chunked decode."""
    kw = dict(prefill_chunk=8, scheduler="mixed", attn_impl="paged_kernel",
              kv_num_pages=4 * 7 + 1)
    _, plain = _run(setup, None, **kw)
    eng, spec = _run(setup, SpecConfig(draft_fmt="mxint4", k=4), **kw)
    assert spec == plain
    assert eng.stats["spec_ticks"] > 0
    # chunk ticks never speculate: a tick with prefill work has no drafts
    for t in eng.tick_trace:
        if t["prefill_chunks"]:
            assert t["draft_execs"] == 0
    _no_leak(eng)


def test_spec_tick_trace_splits_draft_and_verify(setup):
    eng, _ = _run(setup, SpecConfig(draft_fmt="mxint4", k=4))
    spec_ticks = [t for t in eng.tick_trace if t["draft_execs"]]
    assert len(spec_ticks) == eng.stats["spec_ticks"]
    for t in spec_ticks:
        assert 1 <= t["draft_execs"] <= 4
        assert t["verify_execs"] >= 1
        # a pure spec tick dispatches exactly draft + verify executables
        if not t["prefill_chunks"]:
            assert t["execs"] == t["draft_execs"] + t["verify_execs"]
    # non-spec engines never report spec executables
    eng_p, _ = _run(setup, None)
    assert all(t["draft_execs"] == 0 and t["verify_execs"] == 0
               for t in eng_p.tick_trace)


def test_spec_policy_disables_on_low_acceptance(setup):
    """spec on/off is a policy decision fed by the measured acceptance
    rate: with the draft rung poisoned into garbage (guard off) and a
    high min_acceptance, the engine stops drafting after the measurement
    window — and the streams still match plain decode."""
    fi = FaultInjector(poison_logits={t: None for t in range(256)},
                       poison_fmt="mxint4")
    _, plain = _run(setup, None, logit_guard=False, max_new=12,
                    max_len=48)
    sc = SpecConfig(draft_fmt="mxint4", k=2, min_acceptance=0.9, window=2)
    eng, spec = _run(setup, sc, injector=fi, logit_guard=False,
                     max_new=12, max_len=48)
    assert spec == plain
    st_ = eng.stats
    # it drafted long enough to measure, then the policy cut it off well
    # short of one spec tick per decode tick
    assert st_["spec_ticks"] >= sc.window
    assert st_["spec_ticks"] < st_["ticks"]
    _no_leak(eng)


def test_spec_requires_greedy(setup):
    cfg, api, params, anchor = setup
    eng = _engine(api, anchor, params,
                  speculative=SpecConfig(draft_fmt="mxint4", k=2))
    with pytest.raises(ValueError, match="greedy-only"):
        eng.generate(_reqs(cfg, 1), greedy=False)


def test_spec_rejects_bad_config(setup):
    cfg, api, params, anchor = setup
    with pytest.raises(ValueError, match="k.*>= 1"):
        _engine(api, anchor, params,
                speculative=SpecConfig(draft_fmt="mxint4", k=0))
    with pytest.raises(ValueError, match="bf16"):
        _engine(api, anchor, params,
                speculative=SpecConfig(draft_fmt="bf16"))


def test_spec_draft_fmt_equal_to_pinned_never_drafts(setup):
    """allow_speculation vetoes draft_fmt == pinned (nothing cheaper to
    draft with) — the engine silently runs plain decode."""
    eng, spec = _run(setup, SpecConfig(draft_fmt="mxint8", k=4))
    _, plain = _run(setup, None)
    assert spec == plain
    assert eng.stats["spec_ticks"] == 0


# ---------------------------------------------------------------------------
# acceptance arithmetic (pure helper)
# ---------------------------------------------------------------------------
def test_spec_accept_counts_unit():
    drafts = np.array([[5, 6, 7],      # all match  -> 3 + bonus = 4
                       [5, 9, 7],      # first only -> 1 + bonus = 2
                       [9, 6, 7],      # none       -> bonus only = 1
                       [5, 6, 7]])     # all match, budget-clamped
    anchor = np.array([[5, 6, 7, 8],
                       [5, 6, 7, 8],
                       [5, 6, 7, 8],
                       [5, 6, 7, 8]])
    budgets = np.array([9, 9, 9, 2])
    assert spec_accept_counts(drafts, anchor, budgets).tolist() \
        == [4, 2, 1, 2]
    # budget 0 (masked / dead row) commits nothing
    assert spec_accept_counts(drafts, anchor, np.zeros(4)).tolist() \
        == [0, 0, 0, 0]
    with pytest.raises(ValueError):
        spec_accept_counts(drafts, anchor[:, :3], budgets)


def test_policy_allow_speculation():
    pol = FormatPolicy("mxint8")
    assert pol.allow_speculation("mxint4", "mxint8")
    assert not pol.allow_speculation("mxint8", "mxint8")
    assert not pol.allow_speculation("mxint4", "mxint8",
                                     acceptance_rate=0.1,
                                     min_acceptance=0.5)
    assert pol.allow_speculation("mxint4", "mxint8", acceptance_rate=None,
                                 min_acceptance=0.5)
    pol.quarantine("mxint4")
    assert not pol.allow_speculation("mxint4", "mxint8")


# ---------------------------------------------------------------------------
# free-list exactness across accept/reject patterns
# ---------------------------------------------------------------------------
def _rollback_case(eng, rows, frontier, slot):
    """Drive _rollback_slot_pages on a synthetic block table and check it
    against the spec: pages past ceil(frontier/page) freed exactly once,
    earlier pages and every other row byte-identical."""
    bt = np.array(rows, np.int32)
    before = bt.copy()
    free: list = []
    freed0 = eng._kv_pages_freed
    eng._rollback_slot_pages(free, bt, slot, frontier)
    keep = -(-frontier // PS)
    expect_drop = [int(p) for p in before[slot, keep:] if p != 0]
    assert sorted(free) == sorted(expect_drop)
    assert eng._kv_pages_freed - freed0 == len(expect_drop)
    assert bt[slot, :keep].tolist() == before[slot, :keep].tolist()
    assert not bt[slot, keep:].any()
    others = [i for i in range(bt.shape[0]) if i != slot]
    assert bt[others].tolist() == before[others].tolist()


def test_rollback_pages_seeded_slice(setup):
    """Always-run seeded slice of the hypothesis property below."""
    cfg, api, params, anchor = setup
    eng = _engine(api, anchor, params)
    rng = np.random.default_rng(11)
    for _ in range(50):
        nrows, width = rng.integers(1, 5), rng.integers(1, 6)
        rows = np.zeros((nrows, width), np.int64)
        for i in range(nrows):
            held = rng.integers(0, width + 1)
            rows[i, :held] = rng.choice(
                np.arange(1, 64), size=held, replace=False)
        slot = int(rng.integers(0, nrows))
        frontier = int(rng.integers(0, width * PS + 1))
        _rollback_case(eng, rows.tolist(), frontier, slot)


@hypothesis.given(
    rows=st.lists(st.lists(st.integers(0, 63), min_size=1, max_size=5),
                  min_size=1, max_size=4),
    frontier=st.integers(0, 48),
    slot_pick=st.integers(0, 3))
@hypothesis.settings(deadline=None, max_examples=50)
def test_rollback_pages_property(setup, rows, frontier, slot_pick):
    """After ANY accept/reject pattern — i.e. any (block table, frontier)
    pair — the rollback frees exactly the nonzero pages past the frontier
    page and touches nothing else."""
    cfg, api, params, anchor = setup
    eng = _engine(api, anchor, params)
    width = max(len(r) for r in rows)
    padded = [r + [0] * (width - len(r)) for r in rows]
    _rollback_case(eng, padded, frontier, slot_pick % len(rows))


def test_spec_free_list_exact_seeded_waves(setup):
    """End-to-end seeded slice: random wave shapes x {clean, poisoned}
    drafts. Every wave must drain with alloc == freed and a stream
    identical to plain anchor decode."""
    cfg, api, params, anchor = setup
    rng = np.random.default_rng(3)
    for wave in range(3):
        n = int(rng.integers(2, 5))
        max_new = int(rng.integers(3, 10))
        k = int(rng.integers(1, 5))
        seed = int(rng.integers(0, 1 << 16))
        poisoned = wave % 2 == 1
        fi = FaultInjector(poison_logits={t: None for t in range(256)},
                           poison_fmt="mxint4") if poisoned else None
        reqs_p = _reqs(cfg, n, max_new=max_new, seed=seed)
        reqs_s = _reqs(cfg, n, max_new=max_new, seed=seed)
        _engine(api, anchor, params, logit_guard=False).generate(
            reqs_p, greedy=True, fmt_override="mxint8")
        eng = _engine(api, anchor, params, logit_guard=False,
                      speculative=SpecConfig(draft_fmt="mxint4", k=k),
                      fault_injector=fi)
        eng.generate(reqs_s, greedy=True, fmt_override="mxint8")
        assert _streams(reqs_s) == _streams(reqs_p), \
            f"wave {wave} (k={k}, poisoned={poisoned})"
        _no_leak(eng)
        assert eng.stats["spec_ticks"] > 0


# ---------------------------------------------------------------------------
# full contract matrix (slow; CI runs it non-blocking)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("fused", [False, True])
@pytest.mark.parametrize("attn", ["gather", "paged_kernel"])
@pytest.mark.parametrize("draft", ["mxint4", "mxint6"])
@pytest.mark.parametrize("k", [1, 4])
def test_spec_matrix(setup, fused, attn, draft, k):
    _, plain = _run(setup, None, fused=fused, attn_impl=attn)
    eng, spec = _run(setup, SpecConfig(draft_fmt=draft, k=k),
                     fused=fused, attn_impl=attn)
    assert spec == plain, (fused, attn, draft, k)
    assert eng.stats["spec_ticks"] > 0
    _no_leak(eng)


@pytest.mark.slow
@pytest.mark.parametrize("fused,attn", [(False, "paged_kernel"),
                                        (True, "gather")])
def test_spec_poisoned_draft_matrix(setup, fused, attn):
    """The adversarial acceptance~0 case on the contract corners the fast
    test doesn't cover."""
    fi = FaultInjector(poison_logits={t: None for t in range(256)},
                       poison_fmt="mxint4")
    _, plain = _run(setup, None, logit_guard=False, fused=fused,
                    attn_impl=attn)
    eng, spec = _run(setup, SpecConfig(draft_fmt="mxint4", k=4),
                     injector=fi, logit_guard=False, fused=fused,
                     attn_impl=attn)
    assert spec == plain, (fused, attn)
    assert eng.stats["spec_rejected"] > 0
    _no_leak(eng)
