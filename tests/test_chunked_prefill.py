"""Chunked prefill admission: the scheduler state machine that bounds
per-tick work (docs/serving_internals.md "Admission & scheduling").

The contract under test: splitting a prompt into ``prefill_chunk``-token
chunks interleaved with decode ticks is a pure *re-scheduling* of the same
computation — token streams stay bit-identical to monolithic admission
(greedy AND seeded sampling, dense AND paged KV, densify AND fused serving
contracts), while no scheduler tick ever runs more than one chunk of
prefill plus one decode step. Under the paged layout, chunk N's pages are
allocated at chunk N; a partial admission that exhausts the pool must
release its pages and requeue, never leak or truncate.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import make_anchor
from repro.core.qat import QATConfig
from repro.models import get_model
from repro.serve.engine import ElasticEngine, Request

QAT = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8", block_size=32)
PS = 8          # page size
CHUNK = 8       # prefill chunk (== one page, the paged-layout default)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("smollm-135m")
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT)
    return cfg, api, params, anchor


def _engine(api, anchor, params, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 48)
    return ElasticEngine(api, anchor, param_template=params, **kw)


def _reqs(cfg, n, max_new=5, plens=(8, 21, 13), seed=7):
    """Mixed lengths on purpose: multi-chunk, chunk-aligned and
    non-multiple-of-chunk prompts in one workload."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, plens[i % len(plens)])
                    .astype(np.int32), max_new=max_new) for i in range(n)]


def _streams(api, anchor, params, cfg, chunk, *, greedy=True, fmt="mxint8",
             n=4, **kw):
    eng = _engine(api, anchor, params, prefill_chunk=chunk, **kw)
    reqs = _reqs(cfg, n)
    eng.generate(reqs, greedy=greedy, fmt_override=fmt)
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], eng


@pytest.mark.parametrize("kv,fused", [("dense", False), ("paged", False),
                                      ("paged", True)])
def test_chunked_matches_monolithic_greedy(setup, kv, fused):
    """Acceptance gate: greedy streams bit-identical chunked vs monolithic,
    across KV layouts and serving contracts."""
    cfg, api, params, anchor = setup
    kw = dict(fused=fused)
    if kv == "paged":
        kw.update(kv_layout="paged", kv_page_size=PS)
    mono, _ = _streams(api, anchor, params, cfg, None, **kw)
    chunked, eng = _streams(api, anchor, params, cfg, CHUNK, **kw)
    assert mono == chunked
    assert eng.stats["prefill_chunk"] == CHUNK


@pytest.mark.slow
@pytest.mark.parametrize("fmt", ["bf16", "mxint4"])
def test_chunked_matches_monolithic_other_formats(setup, fmt):
    cfg, api, params, anchor = setup
    mono, _ = _streams(api, anchor, params, cfg, None, fmt=fmt)
    chunked, _ = _streams(api, anchor, params, cfg, CHUNK, fmt=fmt)
    assert mono == chunked


def test_chunked_matches_monolithic_sampled(setup):
    """Seeded sampling: the slot RNG stream is seeded at prefill
    *completion* (not admission start), so the chunked scheduler's extra
    mid-prefill decode ticks cannot skew a request's draws."""
    cfg, api, params, anchor = setup
    kw = dict(seed=3, temperature=1.0, top_p=0.9)
    mono, _ = _streams(api, anchor, params, cfg, None, greedy=False, **kw)
    chunked, _ = _streams(api, anchor, params, cfg, CHUNK, greedy=False,
                          **kw)
    assert mono == chunked


def test_prompt_not_multiple_of_chunk(setup):
    """A final partial chunk (21 % 8 = 5, bucketed to 8 with exact masking)
    must not perturb the stream — compare against monolithic on dense and
    paged in one go."""
    cfg, api, params, anchor = setup
    for kw in (dict(), dict(kv_layout="paged", kv_page_size=PS)):
        out = {}
        for chunk in (None, CHUNK):
            eng = _engine(api, anchor, params, prefill_chunk=chunk, **kw)
            reqs = _reqs(cfg, 2, plens=(21, 13), seed=11)
            eng.generate(reqs, fmt_override="mxint8")
            out[chunk] = [r.out_tokens for r in reqs]
        assert out[None] == out[CHUNK], kw


def test_tick_work_is_bounded(setup):
    """The scheduling claim itself, via the engine's trace counters: with
    prefill_chunk set, NO tick runs more than one chunk of prefill plus one
    decode step — while monolithic admission demonstrably stalls a tick for
    the whole bucketed prompt."""
    cfg, api, params, anchor = setup
    long_req = _reqs(cfg, 3, plens=(30, 8, 8), seed=2)

    eng = _engine(api, anchor, params, prefill_chunk=CHUNK)
    eng.generate([Request(r.rid, r.prompt.copy(), r.max_new)
                  for r in long_req], fmt_override="mxint8")
    assert eng.tick_trace, "chunked run recorded no ticks"
    for t in eng.tick_trace:
        assert t["prefill_chunks"] <= 1
        assert t["prefill_tokens"] <= CHUNK
        assert t["decode"] <= 1

    mono = _engine(api, anchor, params)
    mono.generate([Request(r.rid, r.prompt.copy(), r.max_new)
                   for r in long_req], fmt_override="mxint8")
    # the 30-token prompt buckets to 32: monolithic admission does all of it
    # (and possibly more prompts) inside a single tick
    assert max(t["prefill_tokens"] for t in mono.tick_trace) >= 32


def test_chunk_pages_allocated_per_chunk(setup):
    """Pages for chunk N are allocated at chunk N, not all upfront: a pool
    exactly sized for the final footprint still admits a long prompt, and
    the high-water mark grows with the cursor."""
    cfg, api, params, anchor = setup
    eng = _engine(api, anchor, params, kv_layout="paged", kv_page_size=PS,
                  prefill_chunk=CHUNK, kv_num_pages=5, batch_slots=1)
    reqs = _reqs(cfg, 1, plens=(22,), max_new=3, seed=4)
    eng.generate(reqs, fmt_override="mxint8")
    st = eng.stats
    assert all(r.done for r in reqs)
    # 3 prefill chunks -> 3 pages, one per chunk; decode stops at position
    # 23 so the 4th page is never touched (and a 4-page upfront grab would
    # have been wasted capacity for the pool's lifetime)
    assert st["kv_pages_alloc"] == st["kv_pages_freed"] == 3
    assert st["admission_requeues"] == 0


def test_pool_exhaustion_mid_prefill_requeues_not_leaks(setup):
    """Partial admission that starves the pool releases its pages and goes
    back to the queue; once the running slot retires and frees pages, the
    requeued prompt admits from chunk 0 and the stream matches a roomy
    run. End state leaks nothing (alloc == freed)."""
    cfg, api, params, anchor = setup
    rng = np.random.default_rng(1)
    mk = lambda: [Request(rid=0, prompt=rng0.copy(), max_new=8),
                  Request(rid=1, prompt=rng1.copy(), max_new=3)]
    rng0 = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    rng1 = rng.integers(0, cfg.vocab, 22).astype(np.int32)

    roomy = _engine(api, anchor, params, max_len=32, kv_layout="paged",
                    kv_page_size=PS, prefill_chunk=CHUNK)
    ref = mk()
    roomy.generate(ref, fmt_override="mxint8")

    # 4 allocatable pages: slot 0 (6-token prompt, decode to pos 13) holds 2
    # while the 22-token prompt needs 3 for prefill alone -> mid-prefill
    # exhaustion, requeue, retry after slot 0 retires.
    eng = _engine(api, anchor, params, max_len=32, kv_layout="paged",
                  kv_page_size=PS, prefill_chunk=CHUNK, kv_num_pages=5)
    reqs = mk()
    eng.generate(reqs, fmt_override="mxint8")
    st = eng.stats
    assert all(r.done for r in reqs)
    assert st["admission_requeues"] >= 1
    assert st["kv_pages_alloc"] == st["kv_pages_freed"]       # no leak
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in ref]


def test_pool_exhaustion_with_nothing_running_fails_the_request(setup):
    """Requeueing only makes sense if a running slot can free pages; a lone
    prompt that can never fit fails fast at admission — FAILED_CAPACITY on
    the request itself (its page demand is checked against the WHOLE pool
    before any allocation), not an exception out of generate() and not a
    wedged queue. The engine stays serviceable and nothing leaks."""
    cfg, api, params, anchor = setup
    from repro.serve.engine import RequestStatus
    eng = _engine(api, anchor, params, max_len=32, kv_layout="paged",
                  kv_page_size=PS, prefill_chunk=CHUNK, kv_num_pages=2)
    reqs = _reqs(cfg, 1, plens=(22,), max_new=3)
    eng.generate(reqs, fmt_override="mxint8")     # must NOT raise
    (r,) = reqs
    assert r.done and r.status is RequestStatus.FAILED_CAPACITY
    assert "KV page" in r.error and "pool has only" in r.error
    st = eng.stats
    assert st["kv_pages_alloc"] == st["kv_pages_freed"] == 0  # never touched
    assert st["request_statuses"] == {"failed_capacity": 1}


def test_chunked_rejects_unsupported_configs(setup):
    """Recurrent mixers cannot resume prefill mid-prompt; paged chunks must
    land on page boundaries."""
    cfg_r = get_reduced("rwkv6-7b")
    api_r = get_model(cfg_r, None)
    params_r = api_r.init_params(jax.random.PRNGKey(0))
    anchor_r = make_anchor(params_r, QAT)
    with pytest.raises(ValueError, match="pure-attention"):
        ElasticEngine(api_r, anchor_r, batch_slots=2, max_len=32,
                      param_template=params_r, prefill_chunk=CHUNK)

    cfg, api, params, anchor = setup
    with pytest.raises(ValueError, match="multiple of"):
        _engine(api, anchor, params, kv_layout="paged", kv_page_size=PS,
                prefill_chunk=PS + 4)


def test_auto_chunk_resolution(setup):
    cfg, api, params, anchor = setup
    eng = _engine(api, anchor, params, kv_layout="paged", kv_page_size=PS,
                  prefill_chunk="auto")
    assert eng.prefill_chunk == PS                 # one KV page
    eng2 = _engine(api, anchor, params, prefill_chunk="auto")
    assert eng2.prefill_chunk == 64                # dense pow2 bucket cap
    assert eng2.prompt_capacity == eng2.max_len - 1
