"""MX quantize/dequantize: unit + hypothesis property tests."""
try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:      # property tests skip; unit tests below still run
    from _hypothesis_stub import hnp, hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (decode_fp, dequantize, encode_fp, get_format,
                        quantize, quantize_dequantize,
                        quantize_fp_element_value)

ALL_FORMATS = [f"mxint{b}" for b in range(2, 9)] + \
              [f"mxfp{b}" for b in range(4, 9)]


def _rand(shape, seed=0, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32) * scale)


@pytest.mark.parametrize("name", ALL_FORMATS)
@pytest.mark.parametrize("bs", [16, 32, 64])
def test_reconstruction_error_bound(name, bs):
    """Per-element error bound.

    With X = 2^(floor(log2 max|V|) − emax), elements satisfy |V/X| < 2^(emax+1).
    MXINT: rounding error ≤ 0.5; symmetric clip at 2^(b-1)−1 adds a gap < 1.
    MXFP:  rounding ≤ ulp/2 per binade; saturation gap = 2^(emax+1) − fp_max.
    """
    fmt = get_format(name, bs)
    v = _rand((8, 256), seed=1)
    t = quantize(v, fmt, axis=-1)
    vq = dequantize(t)
    vb = np.asarray(v).reshape(8, 256 // bs, bs)
    scale = np.exp2(np.asarray(t.scale_exp, np.float32))[..., None]
    err = np.abs(np.asarray(vq).reshape(vb.shape) - vb)
    if fmt.kind == "int":
        bound = 1.0                      # max(0.5 rounding, <1 clip gap)
    else:
        clip_gap = 2.0 ** (fmt.emax + 1) - fmt.fp_max
        bound = max(clip_gap, 2.0 ** (fmt.emax - fmt.mbits) / 2)
    assert np.all(err <= scale * bound + 1e-7)


@pytest.mark.parametrize("name", ALL_FORMATS)
def test_idempotent(name):
    """quantize(dequantize(q)) == q (the value set is a fixed point)."""
    fmt = get_format(name, 32)
    v = _rand((4, 128), seed=2, scale=3.0)
    t1 = quantize(v, fmt)
    v1 = dequantize(t1)
    t2 = quantize(v1, fmt)
    v2 = dequantize(t2)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


@pytest.mark.parametrize("name", ALL_FORMATS)
def test_fused_equals_two_step(name):
    fmt = get_format(name, 32)
    v = _rand((4, 128), seed=3)
    np.testing.assert_array_equal(
        np.asarray(quantize_dequantize(v, fmt)),
        np.asarray(dequantize(quantize(v, fmt))))


@pytest.mark.parametrize("axis", [0, 1, 2, -1])
def test_block_axis(axis):
    fmt = get_format("mxint6", 32)
    v = _rand((32, 64, 96), seed=4)
    t = quantize(v, fmt, axis=axis)
    vq = dequantize(t)
    assert vq.shape == v.shape
    ax = axis % 3
    expected_scale_shape = list(v.shape)
    expected_scale_shape[ax] //= 32
    # scale_exp has the block axis moved last in blocked layout
    assert t.scale_exp.size == np.prod(v.shape) // 32


def test_zero_block():
    fmt = get_format("mxint8", 32)
    v = jnp.zeros((2, 64))
    t = quantize(v, fmt)
    np.testing.assert_array_equal(np.asarray(t.codes), 0)
    np.testing.assert_array_equal(np.asarray(dequantize(t)), 0.0)


def test_scale_matches_paper_formula():
    """shared_exp = floor(log2 max|V|) − emax(f)  (Eq. 3/5)."""
    for name in ["mxint8", "mxint4", "mxfp8", "mxfp4"]:
        fmt = get_format(name, 32)
        v = _rand((16, 320), seed=5, scale=7.3)
        t = quantize(v, fmt)
        vb = np.asarray(v, np.float64).reshape(16, 10, 32)
        bmax = np.abs(vb).max(-1)
        want = np.floor(np.log2(bmax)) - fmt.emax
        np.testing.assert_array_equal(
            np.asarray(t.scale_exp, np.int32), want.astype(np.int32))


@pytest.mark.parametrize("name", [f"mxfp{b}" for b in range(4, 9)])
def test_fp_encode_decode_roundtrip(name):
    fmt = get_format(name, 32)
    # every representable value round-trips
    vals = quantize_fp_element_value(
        jnp.linspace(-fmt.fp_max, fmt.fp_max, 4097), fmt)
    rt = decode_fp(encode_fp(vals, fmt), fmt)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(vals))


def test_e4m3_saturates_at_448():
    fmt = get_format("mxfp8", 32)
    q = quantize_fp_element_value(jnp.asarray([500.0, -10000.0, 448.0]), fmt)
    np.testing.assert_array_equal(np.asarray(q), [448.0, -448.0, 448.0])


def test_mxint_symmetric_clip():
    fmt = get_format("mxint4", 32)
    v = _rand((2, 64), seed=6)
    t = quantize(v, fmt)
    assert int(jnp.min(t.codes)) >= -7 and int(jnp.max(t.codes)) <= 7


# ---------------------------------------------------------------------------
# Property tests (hypothesis)
# ---------------------------------------------------------------------------
@hypothesis.given(
    arr=hnp.arrays(np.float32, (2, 64),
                   elements=st.floats(-1e4, 1e4, width=32,
                                      allow_nan=False, allow_infinity=False)),
    name=st.sampled_from(ALL_FORMATS),
)
@hypothesis.settings(deadline=None, max_examples=40)
def test_prop_dequant_in_convex_hull(arr, name):
    """Reconstruction never exceeds the block max in magnitude by > 1 quantum."""
    fmt = get_format(name, 32)
    v = jnp.asarray(arr)
    vq = np.asarray(dequantize(quantize(v, fmt)))
    bmax = np.abs(arr).reshape(2, 2, 32).max(-1, keepdims=True)
    assert np.all(np.abs(vq.reshape(2, 2, 32)) <= 2 * bmax + 1e-30)


@hypothesis.given(
    arr=hnp.arrays(np.float32, (1, 32),
                   elements=st.floats(-1e6, 1e6, width=32,
                                      allow_nan=False, allow_infinity=False)),
)
@hypothesis.settings(deadline=None, max_examples=60)
def test_prop_idempotence_mxint8(arr):
    fmt = get_format("mxint8", 32)
    v1 = dequantize(quantize(jnp.asarray(arr), fmt))
    v2 = dequantize(quantize(v1, fmt))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


@hypothesis.given(scale=st.floats(1e-20, 1e20))
@hypothesis.settings(deadline=None, max_examples=30)
def test_prop_scale_equivariance(scale):
    """Quantizing 2^k·V scales the reconstruction by exactly 2^k."""
    k = int(np.floor(np.log2(scale)))
    fmt = get_format("mxint6", 32)
    v = _rand((1, 64), seed=7)
    a = np.asarray(dequantize(quantize(v, fmt)), np.float64)
    b = np.asarray(dequantize(quantize(v * (2.0 ** k), fmt)), np.float64)
    np.testing.assert_allclose(b, a * 2.0 ** k, rtol=0, atol=0)
