"""Root pytest conftest: a 2-device CPU platform for the whole test session.

The tensor-parallel serving tests (tests/test_mesh_serving.py) compare a
``(1, 2)`` CPU mesh against the single-device engine, which requires the
host platform to expose 2 devices BEFORE the first ``import jax`` anywhere
in the session — exactly what a root conftest guarantees (pytest imports it
before collecting any test module).

Same contract as ``launch/dryrun.py``: append to any pre-set XLA_FLAGS
rather than overwriting, and skip entirely when the caller already pinned a
host device count (their setting wins). Single-device behavior is
unaffected — nothing shards unless a test builds a mesh.
"""
import os

_FLAG = "--xla_force_host_platform_device_count"
_existing = os.environ.get("XLA_FLAGS", "")
if _FLAG not in _existing:
    os.environ["XLA_FLAGS"] = f"{_existing} {_FLAG}=2".strip()
